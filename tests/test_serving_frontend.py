"""Async SLO-aware serving front end + virtual clock (DESIGN.md §12).

Everything here runs on the simulated clock: arrivals, TTFT, queue
delay and wall-time telemetry are deterministic functions of (trace
seed, StepCost), so these are exact tests, not tolerance games.

The property suite exists twice: seeded-rng parametrized versions that
always run, and hypothesis-widened versions (same invariant functions,
randomized policy knobs) that run where hypothesis is installed.
"""

import asyncio
import dataclasses

import jax
import numpy as np
import pytest

from repro.serve.clock import (Clock, RealClock, StepCost, VirtualClock,
                               ensure_clock)
from repro.serve.engine import Engine
from repro.serve.frontend import AdmissionError, AsyncEngine
from repro.serve.scheduler import Request

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover
    HAVE_HYPOTHESIS = False

COST = StepCost()                        # the default deterministic model


# ---------------------------------------------------------------------------
# fixtures / helpers
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def f32_model():
    from repro.configs import get_reduced_config
    from repro.models.registry import build_model
    cfg = get_reduced_config("qwen1_5_4b").reduced(
        d_model=512, d_ff=1024, num_layers=2, vocab_size=1024,
        num_heads=8, num_kv_heads=8, head_dim=64, dtype="float32")
    model = build_model(cfg)
    params, axes = model.init(jax.random.PRNGKey(0))
    return model, params, axes


def make_engine(f32_model, *, max_len=256, max_batch=2, max_prompt=32,
                clock=None):
    model, params, axes = f32_model
    return Engine(model, params, axes, max_len=max_len, max_batch=max_batch,
                  max_prompt=max_prompt, prepack=False, clock=clock)


def _prompt(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 1024, size=n).astype(np.int32)


def rand_trace(seed, n, *, mean_gap_s=0.002, tiers=3,
               tenants=("acme", "bolt", "crux"), max_prompt=24):
    """Seeded open-loop trace with random interleavings of arrival,
    prompt length, decode budget (incl. the instant-finish
    max_new_tokens=1 path), priority and tenant."""
    rng = np.random.default_rng(seed)
    t, reqs = 0.0, []
    for i in range(n):
        t += float(rng.exponential(mean_gap_s))
        p = int(rng.integers(2, max_prompt))
        reqs.append(Request(
            tokens=rng.integers(0, 1024, size=p).astype(np.int32),
            max_new_tokens=int(rng.integers(1, 6)), rid=i,
            arrival_time=t, priority=int(rng.integers(0, tiers)),
            tenant=str(tenants[int(rng.integers(0, len(tenants)))])))
    return reqs


def check_invariants(afe, streams, stats, n_submitted):
    """The §12 conservation laws: no slot leaks, every stream reaches a
    terminal state, and the telemetry counts tie out exactly — across
    ANY interleaving of arrivals, completions, rejections, capacity
    truncation and starvation escalations."""
    # slot conservation: every slot back in the free pool, none live
    assert not afe.sched.active
    assert sorted(afe.sched.free) == list(range(afe.sched.slots))
    # every stream terminal, exactly one terminal state each
    assert len(streams) == n_submitted
    n_rej = sum(s.rejected for s in streams)
    n_uns = sum(s.result is None and not s.rejected for s in streams)
    n_adm = sum(s.result is not None for s in streams)
    assert all(s.done for s in streams)
    assert n_rej + n_uns + n_adm == n_submitted
    assert stats.rejected == n_rej
    assert stats.unserved == n_uns
    assert stats.admitted == n_adm
    assert stats.completed == sum(s.completed for s in streams)
    # token conservation: the stats ledger equals the streamed tokens
    assert stats.generated_tokens == sum(len(s.tokens) for s in streams)
    for s in streams:
        if s.result is not None:
            assert list(s.result.tokens) == s.tokens
            assert s.queue_delay is not None and s.queue_delay >= 0
            assert s.ttft is not None and s.ttft >= 0
            # stream timestamps never rewind
            assert all(b >= a for a, b in zip(s.token_times,
                                              s.token_times[1:]))
        else:
            assert s.tokens == []
    # per-tier ledgers sum to the totals
    assert sum(t.admitted for t in stats.tiers.values()) == n_adm
    assert sum(t.rejected for t in stats.tiers.values()) == n_rej
    assert sum(t.generated_tokens for t in stats.tiers.values()) \
        == stats.generated_tokens


# ---------------------------------------------------------------------------
# the clock seam
# ---------------------------------------------------------------------------


def test_clock_protocol():
    vc = VirtualClock(start=2.0)
    assert vc.virtual and isinstance(vc, Clock)
    assert vc.now() == 2.0
    assert vc.advance(0.5) == 2.5
    assert vc.advance_to(2.25) == 2.5          # never rewinds
    with pytest.raises(ValueError):
        vc.advance(-1.0)
    rc = RealClock()
    assert not rc.virtual and isinstance(rc, Clock)
    assert rc.now() <= rc.now()
    with pytest.raises(TypeError):
        rc.advance(1.0)
    assert ensure_clock(None).virtual is False
    assert ensure_clock(vc) is vc


def test_virtual_clock_sleep_advances_without_blocking():
    vc = VirtualClock()

    async def go():
        await vc.sleep(1.5)
        return vc.now()

    assert asyncio.run(go()) == 1.5


def test_step_cost_model():
    c = StepCost(decode_step_s=2e-3, prefill_token_s=1e-5)
    assert c.prefill_s(100) == pytest.approx(1e-3)
    assert c.decode_step_s == 2e-3


def test_scheduler_virtual_wall_accounting(f32_model):
    """On the virtual clock the scheduler's wall/compile/throughput
    telemetry is an EXACT function of its own counters and the cost
    model — the §12 retrofit that replaces wall-clock-noise telemetry
    with checkable numbers."""
    eng = make_engine(f32_model, max_len=128, clock=VirtualClock())
    reqs = [Request(tokens=_prompt(n, seed=n), max_new_tokens=m, rid=i)
            for i, (n, m) in enumerate([(5, 4), (12, 2), (20, 6), (9, 3)])]
    _, stats = eng.serve_queue(reqs)
    want = (stats.compile_s
            + stats.steps * COST.decode_step_s
            + COST.prefill_s(stats.prompt_tokens + stats.prompt_pad_tokens))
    assert stats.wall_s == pytest.approx(want)
    assert stats.tokens_per_s == pytest.approx(
        stats.generated_tokens / (stats.wall_s - stats.compile_s))
    # cold programs each charged exactly once at the modeled price
    assert stats.compile_s == pytest.approx(
        COST.compile_s * round(stats.compile_s / COST.compile_s))
    # a second identical queue on the warm engine charges no compile
    _, stats2 = eng.serve_queue([dataclasses.replace(r) for r in reqs])
    assert stats2.compile_s == 0.0
    assert stats2.wall_s == pytest.approx(
        stats2.steps * COST.decode_step_s
        + COST.prefill_s(stats2.prompt_tokens + stats2.prompt_pad_tokens))


# ---------------------------------------------------------------------------
# Request back-compat (arrival_time / priority / tenant satellites)
# ---------------------------------------------------------------------------


def test_request_json_roundtrip_and_old_records():
    r = Request(tokens=np.asarray([3, 1, 4], np.int32), max_new_tokens=7,
                eos_id=2, rid="abc", arrival_time=1.25, priority=2,
                tenant="acme")
    back = Request.from_json(r.to_json())
    assert back.to_json() == r.to_json()
    assert list(back.tokens) == [3, 1, 4]
    # a pre-§12 serialized record (no arrival/priority/tenant) loads
    # with the closed-loop defaults
    old = {"tokens": [5, 6], "max_new_tokens": 3, "eos_id": None,
           "rid": 0}
    r2 = Request.from_json(old)
    assert (r2.arrival_time, r2.priority, r2.tenant) == (0.0, 0, "default")
    # and old positional/keyword construction still works unchanged
    r3 = Request(np.asarray([1], np.int32), 4, None, "rid")
    assert r3.priority == 0 and r3.tenant == "default"


def test_old_serve_queue_callsites_unchanged(f32_model):
    """The §8 closed-loop entry point neither requires nor reacts to the
    new fields: a pre-§12 caller gets the same results object shape and
    ordering as before."""
    eng = make_engine(f32_model, max_len=128)
    reqs = [Request(tokens=_prompt(n, seed=n), max_new_tokens=3, rid=n)
            for n in (5, 11)]
    results, stats = eng.serve_queue(reqs)
    assert [r.rid for r in results] == [5, 11]
    assert all(r.completed and len(r.tokens) == 3 for r in results)
    assert stats.admitted == stats.completed == 2
    # JSON round-tripped requests serve identically
    results2, _ = eng.serve_queue(
        [Request.from_json(r.to_json()) for r in reqs])
    for a, b in zip(results, results2):
        np.testing.assert_array_equal(a.tokens, b.tokens)


# ---------------------------------------------------------------------------
# byte-identity with the closed-loop scheduler
# ---------------------------------------------------------------------------


SPEC = [(5, 4), (12, 2), (20, 6), (9, 3), (3, 5), (7, 1)]


def _spec_requests():
    return [Request(tokens=_prompt(n, seed=n), max_new_tokens=m, rid=i)
            for i, (n, m) in enumerate(SPEC)]


def test_frontend_byte_identical_to_serve_queue(f32_model):
    """Default-policy front end on an all-arrived-at-once trace produces
    BYTE-identical tokens, admission clocks and queue waits to
    ``Engine.serve_queue`` — both drive the same step-driven core."""
    eng = make_engine(f32_model, max_len=128, clock=VirtualClock())
    results, stats = eng.serve_queue(_spec_requests())
    afe = AsyncEngine(eng, clock=VirtualClock())
    streams, astats = afe.simulate(_spec_requests())
    assert len(streams) == len(results)
    for r, s in zip(results, streams):
        assert s.tokens == list(r.tokens)
        assert s.result.admitted_at == r.admitted_at
        assert s.result.finished_at == r.finished_at
        assert s.result.queue_steps == r.queue_steps
        assert s.result.completed == r.completed
    assert (astats.steps, astats.admitted, astats.completed) \
        == (stats.steps, stats.admitted, stats.completed)
    assert astats.generated_tokens == stats.generated_tokens


def test_simulate_is_deterministic(f32_model):
    """Two simulations of the same seeded trace agree exactly: tokens,
    every timestamp, and the whole stats ledger."""
    runs = []
    for _ in range(2):
        # fresh engine per run: the warm-program set is engine state, so
        # an identical COLD run is the reproducibility contract
        eng = make_engine(f32_model, max_len=512, max_batch=2,
                          clock=VirtualClock())
        afe = AsyncEngine(eng, queue_limit=6, prefill_budget=16,
                          starvation_steps=16, clock=VirtualClock())
        runs.append(afe.simulate(rand_trace(7, 14)))
    (s1, st1), (s2, st2) = runs
    for a, b in zip(s1, s2):
        assert a.tokens == b.tokens
        assert a.token_times == b.token_times
        assert a.rejected == b.rejected and a.completed == b.completed
        assert (a.ttft is None) == (b.ttft is None)
        if a.ttft is not None:
            assert a.ttft == b.ttft
    for f in ("steps", "admitted", "completed", "unserved", "rejected",
              "generated_tokens", "prompt_tokens", "prompt_pad_tokens",
              "queue_steps_total", "compile_s", "wall_s"):
        assert getattr(st1, f) == getattr(st2, f), f


# ---------------------------------------------------------------------------
# property suite (seeded) — slot leaks, starvation, backpressure, budget
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed,queue_limit,budget,max_len", [
    (0, 32, None, 512),
    (1, 3, 16, 512),          # tight queue -> rejections
    (2, 32, 8, 160),          # tight budget + tight capacity -> truncation
    (3, 2, None, 96),         # capacity exhaustion -> unserved drops
])
def test_no_slot_leak_random_interleavings(f32_model, seed, queue_limit,
                                           budget, max_len):
    eng = make_engine(f32_model, max_len=max_len, clock=VirtualClock())
    trace = rand_trace(seed, 12)
    afe = AsyncEngine(eng, queue_limit=queue_limit, prefill_budget=budget,
                      starvation_steps=16, clock=VirtualClock())
    streams, stats = afe.simulate(trace)
    check_invariants(afe, streams, stats, len(trace))


def test_low_priority_tenants_not_starved(f32_model):
    """A continuous stream of tier-0 arrivals must not starve a tier-2
    tenant: starvation aging escalates it after ``starvation_steps``
    decode steps, bounding its wait."""
    eng = make_engine(f32_model, max_len=1024, max_batch=1,
                      clock=VirtualClock())
    starve = 8
    trace = [Request(tokens=_prompt(6, seed=100 + i), max_new_tokens=4,
                     rid=f"hi{i}", arrival_time=i * 1e-4, priority=0,
                     tenant="flood")
             for i in range(12)]
    trace.append(Request(tokens=_prompt(6, seed=50), max_new_tokens=4,
                         rid="lo", arrival_time=1e-4, priority=2,
                         tenant="patient"))
    afe = AsyncEngine(eng, queue_limit=64, starvation_steps=starve,
                      clock=VirtualClock())
    streams, stats = afe.simulate(trace)
    check_invariants(afe, streams, stats, len(trace))
    lo = next(s for s in streams if s.rid == "lo")
    assert lo.completed
    # aging bound: once escalated the request is next in line; with one
    # slot it waits at most the escalation threshold plus one stream's
    # worth of decode steps before admission
    assert lo.queue_steps <= starve + 2 * max(m.max_new_tokens
                                              for m in trace)
    # it must NOT have waited for the whole flood to drain first
    flood_done = [s for s in streams if s.tenant == "flood"]
    assert lo.result.admitted_at < max(s.result.finished_at
                                       for s in flood_done)
    assert stats.tiers[2].completed == 1


def test_tenant_fairness_round_robin(f32_model):
    """Within one tier, two tenants submitting bursts at t=0 are admitted
    alternately (round-robin), not in submission order."""
    eng = make_engine(f32_model, max_len=512, max_batch=1,
                      clock=VirtualClock())
    trace = [Request(tokens=_prompt(5, seed=i), max_new_tokens=2,
                     rid=f"a{i}", tenant="a") for i in range(3)]
    trace += [Request(tokens=_prompt(5, seed=10 + i), max_new_tokens=2,
                      rid=f"b{i}", tenant="b") for i in range(3)]
    afe = AsyncEngine(eng, clock=VirtualClock())
    streams, stats = afe.simulate(trace)
    order = sorted((s for s in streams if s.result is not None),
                   key=lambda s: (s.result.admitted_at, s.queue_steps))
    tenants = [s.tenant for s in order]
    assert tenants == ["a", "b", "a", "b", "a", "b"]


def test_priority_tiers_admit_first(f32_model):
    """With everything queued at once and one slot, tier-0 requests are
    all admitted before tier-1 despite later submission."""
    eng = make_engine(f32_model, max_len=512, max_batch=1,
                      clock=VirtualClock())
    trace = [Request(tokens=_prompt(5, seed=i), max_new_tokens=2,
                     rid=f"lo{i}", priority=1) for i in range(3)]
    trace += [Request(tokens=_prompt(5, seed=10 + i), max_new_tokens=2,
                      rid=f"hi{i}", priority=0) for i in range(3)]
    afe = AsyncEngine(eng, starvation_steps=1000, clock=VirtualClock())
    streams, _ = afe.simulate(trace)
    by_adm = sorted(streams, key=lambda s: s.result.admitted_at)
    assert [s.priority for s in by_adm] == [0, 0, 0, 1, 1, 1]


def test_backpressure_bounded_queue(f32_model):
    """Admission control: with ``queue_limit`` pending the (limit+1)-th
    concurrent submission is rejected immediately, carries no tokens,
    and the accepted ones all complete."""
    eng = make_engine(f32_model, max_len=512, max_batch=1,
                      clock=VirtualClock())
    trace = [Request(tokens=_prompt(6, seed=i), max_new_tokens=8, rid=i,
                     arrival_time=0.0) for i in range(8)]
    afe = AsyncEngine(eng, queue_limit=3, clock=VirtualClock())
    streams, stats = afe.simulate(trace)
    check_invariants(afe, streams, stats, len(trace))
    # all 8 arrive in the same instant, before the scheduler can run:
    # 3 fill the bounded queue, the other 5 bounce
    assert stats.rejected == 5
    assert [s.rejected for s in streams] == [False] * 3 + [True] * 5
    assert all(s.completed for s in streams if not s.rejected)
    assert all(s.tokens == [] for s in streams if s.rejected)


def test_prefill_budget_chunks_admissions(f32_model):
    """Chunk-budgeted prefill: with a live batch, at most
    ``prefill_budget`` prompt tokens are admitted per decode step, so a
    deep queue's prefill work interleaves with decode instead of
    stalling it; unbudgeted, the whole queue admits at one clock."""
    def run(budget):
        eng = make_engine(f32_model, max_len=1024, max_batch=4,
                          clock=VirtualClock())
        trace = [Request(tokens=_prompt(14, seed=i), max_new_tokens=6,
                         rid=i, arrival_time=0.0) for i in range(4)]
        afe = AsyncEngine(eng, prefill_budget=budget, clock=VirtualClock())
        streams, stats = afe.simulate(trace)
        check_invariants(afe, streams, stats, len(trace))
        return streams

    unbudgeted = run(None)
    assert len({s.result.admitted_at for s in unbudgeted}) == 1
    budgeted = run(16)       # length bucket 16 = one admission per step
    adm = sorted(s.result.admitted_at for s in budgeted)
    # the idle batch bypasses the budget (r0) and the initial credit
    # covers r1 at the same clock; r2/r3 each wait for one decode step's
    # worth of fresh credit
    assert [b - a for a, b in zip(adm, adm[1:])] == [0, 1, 1]


def test_submit_rejected_raises_async(f32_model):
    eng = make_engine(f32_model, max_len=256, clock=VirtualClock())
    afe = AsyncEngine(eng, queue_limit=2, clock=VirtualClock())

    async def go():
        await afe.submit(Request(tokens=_prompt(5, seed=0), rid=0))
        await afe.submit(Request(tokens=_prompt(5, seed=1), rid=1))
        with pytest.raises(AdmissionError):
            await afe.submit(Request(tokens=_prompt(5, seed=2), rid=2))
        afe._drop_pending()
        afe.close()
        return True

    assert asyncio.run(go())
    assert afe.stats.rejected == 1


def test_async_driver_streams_tokens_live(f32_model):
    """The asyncio driver on the virtual clock: concurrent producers
    ``await submit``, consume ``async for`` token streams, and the
    result matches the same requests served closed-loop."""
    eng = make_engine(f32_model, max_len=256, clock=VirtualClock())
    reqs = [Request(tokens=_prompt(n, seed=n), max_new_tokens=m, rid=i)
            for i, (n, m) in enumerate([(5, 4), (9, 3)])]

    async def go():
        afe = AsyncEngine(eng, clock=VirtualClock())
        # pin the base clock serve_queue would pick for this queue so
        # the comparison below is byte-exact (run() cannot peek at
        # future arrivals, so by default it opens at the grid maximum)
        afe.open(max(lb for _, lb in map(afe.sched.prepare, reqs)))
        loop_task = asyncio.create_task(afe.run())
        streams = [await afe.submit(r) for r in reqs]
        collected = []
        for s in streams:
            toks = []
            async for tok in s:
                toks.append(tok)
            collected.append(toks)
        afe.request_stop()
        await loop_task
        return streams, collected

    streams, collected = asyncio.run(go())
    ref, _ = eng.serve_queue([dataclasses.replace(r) for r in reqs])
    for s, toks, r in zip(streams, collected, ref):
        assert s.completed
        assert toks == s.tokens == list(r.tokens)
        assert s.ttft is not None and s.ttft > 0


# ---------------------------------------------------------------------------
# hypothesis-widened property suite (runs where hypothesis is installed)
# ---------------------------------------------------------------------------


if HAVE_HYPOTHESIS:
    HSET = settings(max_examples=5, deadline=None)

    @HSET
    @given(seed=st.integers(0, 10_000), queue_limit=st.integers(2, 16),
           budget=st.sampled_from([None, 8, 16, 32]),
           slots=st.integers(1, 2), n=st.integers(1, 10))
    def test_hyp_no_slot_leak(f32_model, seed, queue_limit, budget, slots,
                              n):
        """No slot leak / ledger drift across random arrival-completion
        interleavings and random policy knobs."""
        eng = make_engine(f32_model, max_len=384, clock=VirtualClock())
        afe = AsyncEngine(eng, slots=slots, queue_limit=queue_limit,
                          prefill_budget=budget, starvation_steps=12,
                          clock=VirtualClock())
        trace = rand_trace(seed, n)
        streams, stats = afe.simulate(trace)
        check_invariants(afe, streams, stats, n)

    @HSET
    @given(seed=st.integers(0, 10_000), n=st.integers(1, 6))
    def test_hyp_byte_identity_with_serve_queue(f32_model, seed, n):
        """Default policy == closed-loop scheduler, for random queues."""
        rng = np.random.default_rng(seed)
        spec = [(int(rng.integers(2, 20)), int(rng.integers(1, 5)))
                for _ in range(n)]
        mk = lambda: [Request(tokens=_prompt(p, seed=seed + i),
                              max_new_tokens=m, rid=i)
                      for i, (p, m) in enumerate(spec)]
        eng = make_engine(f32_model, max_len=256, clock=VirtualClock())
        results, _ = eng.serve_queue(mk())
        afe = AsyncEngine(eng, clock=VirtualClock())
        streams, _ = afe.simulate(mk())
        for r, s in zip(results, streams):
            assert s.tokens == list(r.tokens)
            assert s.result.completed == r.completed

    @HSET
    @given(seed=st.integers(0, 10_000))
    def test_hyp_no_starvation(f32_model, seed):
        """Every accepted request terminates (no infinite deferral) no
        matter the tier/tenant mix, given cache capacity."""
        eng = make_engine(f32_model, max_len=2048, clock=VirtualClock())
        afe = AsyncEngine(eng, queue_limit=64, starvation_steps=8,
                          clock=VirtualClock())
        trace = rand_trace(seed, 10, mean_gap_s=5e-4)
        streams, stats = afe.simulate(trace)
        check_invariants(afe, streams, stats, len(trace))
        assert all(s.completed for s in streams if not s.rejected)


# ---------------------------------------------------------------------------
# the SLO scoreboard as a regression test (fixed-seed Poisson trace)
# ---------------------------------------------------------------------------


def test_latency_regression_fixed_seed_poisson():
    """The BENCH_6 scoreboard run at smoke scale, asserted: bounded p99
    TTFT at low offered load, queue delay monotone non-decreasing in
    load, and throughput that rises with offered load (same seeded
    work, time-compressed).  Deterministic on the virtual clock."""
    from benchmarks import serving_slo
    metrics = []
    serving_slo.run(rates=(20.0, 60.0, 180.0), n_requests=16, max_batch=2,
                    prepack=False, collect=metrics)
    assert [m["rate"] for m in metrics] == [20.0, 60.0, 180.0]
    low = metrics[0]
    # at ~1/10th of capacity a first token arrives within a handful of
    # decode-step times (p99 measured 3.8ms; 15ms = headroom, not noise
    # — the number cannot drift on the virtual clock)
    assert low["p99_ttft_s"] < 15e-3
    assert low["rejected"] == 0 and low["unserved"] == 0
    delays = [m["mean_queue_delay_s"] for m in metrics]
    assert all(b >= a for a, b in zip(delays, delays[1:])), delays
    p99s = [m["p99_ttft_s"] for m in metrics]
    assert all(p > 0 for p in p99s)
    tps = [m["tokens_per_s"] for m in metrics]
    assert all(b >= a for a, b in zip(tps, tps[1:])), tps


def test_bench6_json_schema(tmp_path):
    """BENCH_6.json rides the BENCH_5 schema (run.py --json contract)."""
    import json

    from benchmarks.common import write_bench_json
    out = write_bench_json(tmp_path / "BENCH_6.json", "BENCH_6",
                           [("sec12_serving_slo",
                             [("slo_rate20_p99_ttft", "3816", "p50=…")])])
    blob = json.loads(out.read_text())
    assert blob["bench"] == "BENCH_6" and blob["failed_sections"] == 0
    assert blob["sections"][0]["section"] == "sec12_serving_slo"
    row = blob["sections"][0]["rows"][0]
    assert set(row) == {"name", "us_per_call", "derived"}
