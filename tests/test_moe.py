"""MoE sort-based dispatch vs a dense (every-expert) reference."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import moe as MOE


def dense_moe_ref(p, cfg, x):
    """Compute every expert on every token, weight by top-k gates —
    mathematically what capacity-unconstrained routing should produce."""
    b, s, d = x.shape
    t = b * s
    xf = np.asarray(x, np.float32).reshape(t, d)
    logits = xf @ np.asarray(p["router"], np.float32)
    e = logits.shape[1]
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    k = cfg.experts_per_token
    idx = np.argsort(-probs, axis=-1, kind="stable")[:, :k]
    out = np.zeros((t, d), np.float32)
    wg = np.asarray(p["w_gate"], np.float32)
    wu = np.asarray(p["w_up"], np.float32)
    wd = np.asarray(p["w_down"], np.float32)
    for i in range(t):
        ws = probs[i, idx[i]]
        ws = ws / ws.sum()
        for j, ex in enumerate(idx[i]):
            h = xf[i] @ wg[ex]
            h = h / (1 + np.exp(-h)) * (xf[i] @ wu[ex])
            out[i] += ws[j] * (h @ wd[ex])
    if cfg.num_shared_experts:
        hs = xf @ np.asarray(p["ws_gate"], np.float32)
        hs = hs / (1 + np.exp(-hs)) * (xf @ np.asarray(p["ws_up"], np.float32))
        out += hs @ np.asarray(p["ws_down"], np.float32)
    return out.reshape(b, s, d)


@pytest.mark.parametrize("arch", ["olmoe_1b_7b", "deepseek_v2_236b"])
def test_moe_matches_dense_reference(arch):
    cfg = get_reduced_config(arch)
    p, _ = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    p = jax.tree.map(lambda v: v.astype(jnp.float32), p)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    # generous capacity so nothing drops -> must equal the dense reference
    out, aux = MOE.moe_apply(p, cfg, x, capacity_factor=8.0)
    want = dense_moe_ref(p, cfg, x)
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-3, atol=2e-3)
    assert float(aux) >= 0


def test_moe_capacity_drops_are_bounded():
    cfg = get_reduced_config("olmoe_1b_7b")
    p, _ = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model),
                          jnp.bfloat16)
    out, aux = MOE.moe_apply(p, cfg, x, capacity_factor=1.0)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out.astype(jnp.float32)).all())


def test_moe_aux_loss_balanced_router_is_minimal():
    """Uniform routing gives aux ~ coef; concentrated routing gives more."""
    cfg = get_reduced_config("olmoe_1b_7b")
    p, _ = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    # near-zero router -> near-uniform probabilities
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, cfg.d_model),
                          jnp.bfloat16)
    _, aux_uniform = MOE.moe_apply(p, cfg, x)
    assert abs(float(aux_uniform) - cfg.router_aux_coef) < 0.15 * cfg.router_aux_coef
