"""Seeded chaos harness (DESIGN.md §16).

Random fault schedules — failpoint actions, probabilities, fire caps,
and request deadlines all drawn from a per-seed RNG — run against the
serving front end on the VirtualClock and against the tuning queue
under thread contention.  Every schedule replays exactly (seeded
failpoint RNG + virtual clock), so a failure here is a repro, not a
flake.

Invariants under ANY schedule:

* serving: no slot leak, every stream reaches exactly one terminal
  state, and the streams that complete are token-for-token identical
  to the healthy run — degradation changes SPEED, never results;
* with failpoints disarmed the engine reports zero degradations;
* queue: every job is completed exactly once, no matter how many
  injected write failures and lock delays the workers absorbed.
"""

import threading

import jax
import numpy as np
import pytest

from repro.resilience import degrade, failpoints

N_SEEDS = 5

# numerics-neutral fault sites: each models a durability/IO failure
# whose §16 ladder rung preserves results (site, action)
SERVING_SITES = (
    ("registry.load", "raise"),
    ("registry.load", "corrupt"),
    ("registry.flush.before_replace", "raise"),
    ("registry.misses.before_replace", "raise"),
    ("programs.deserialize", "corrupt"),
    ("programs.deserialize", "raise"),
    ("programs.serialize.before_replace", "raise"),
)


@pytest.fixture(autouse=True)
def _clean_failpoints():
    failpoints.reset()
    yield
    failpoints.reset()


@pytest.fixture(scope="module")
def f32_model():
    from repro.configs import get_reduced_config
    from repro.models.registry import build_model
    cfg = get_reduced_config("qwen1_5_4b").reduced(
        d_model=512, d_ff=1024, num_layers=2, vocab_size=1024,
        num_heads=8, num_kv_heads=8, head_dim=64, dtype="float32")
    model = build_model(cfg)
    params, axes = model.init(jax.random.PRNGKey(0))
    return model, params, axes


@pytest.fixture(scope="module")
def prog_dir(tmp_path_factory):
    return tmp_path_factory.mktemp("chaos_programs")


def make_afe(f32_model, prog_dir):
    from repro.serve.clock import VirtualClock
    from repro.serve.engine import Engine
    from repro.serve.frontend import AsyncEngine
    model, params, axes = f32_model
    eng = Engine(model, params, axes, max_len=256, max_batch=2,
                 max_prompt=32, prepack=False, program_cache=prog_dir)
    return eng, AsyncEngine(eng, clock=VirtualClock())


def chaos_trace(seed, n=10):
    from repro.serve.scheduler import Request
    rng = np.random.default_rng(seed)
    t, reqs = 0.0, []
    for i in range(n):
        t += float(rng.uniform(0.0005, 0.004))
        reqs.append(Request(
            tokens=rng.integers(0, 1024,
                                size=int(rng.integers(2, 16)))
            .astype(np.int32),
            max_new_tokens=int(rng.integers(1, 6)), rid=i,
            arrival_time=t))
    return reqs


def with_deadlines(reqs, rng):
    """Random deadlines from a SEPARATE rng, so the request content
    (prompts, budgets, arrivals) is identical to the healthy trace."""
    import dataclasses
    out = []
    for r in reqs:
        d = None
        if rng.random() < 0.3:
            d = r.arrival_time + float(rng.uniform(0.002, 0.05))
        out.append(dataclasses.replace(r, deadline=d))
    return out


def chaos_schedule(rng):
    """Draw one failpoint schedule: a random subset of the neutral
    sites with random probability and fire caps."""
    spec = {}
    for site, action in SERVING_SITES:
        if rng.random() < 0.6:
            spec[site] = {"action": action,
                          "p": float(rng.choice([0.3, 0.7, 1.0])),
                          "times": int(rng.choice([1, 3, -1]))}
    return spec


def check_terminal(afe, streams, stats):
    assert not afe.sched.active                       # no slot leak
    assert sorted(afe.sched.free) == list(range(afe.sched.slots))
    for s in streams:
        assert s.done                                 # exactly one terminal
        assert s.completed + s.rejected + s.cancelled \
            + (s.result is None and not s.rejected
               and not s.cancelled) == 1
    assert stats.generated_tokens == sum(len(s.tokens) for s in streams)


@pytest.mark.parametrize("seed", range(N_SEEDS))
def test_serving_chaos_schedule(f32_model, prog_dir, seed):
    rng = np.random.default_rng(1000 + seed)

    # healthy baseline: same arrivals, no faults, no deadlines
    eng_h, afe_h = make_afe(f32_model, prog_dir)
    healthy, stats_h = afe_h.simulate(chaos_trace(1000 + seed))
    check_terminal(afe_h, healthy, stats_h)
    hr = eng_h.health_report()
    assert hr["healthy"], hr                          # disarmed: zero demotions
    assert stats_h.cancelled == 0 and stats_h.expired == 0
    want = {s.rid: list(s.tokens) for s in healthy if s.completed}

    # chaos run: same requests + random deadlines + random fault schedule
    trace = with_deadlines(chaos_trace(1000 + seed), rng)
    spec = chaos_schedule(rng)
    failpoints.configure(spec, seed=seed)
    eng_c, afe_c = make_afe(f32_model, prog_dir)
    streams, stats = afe_c.simulate(trace)
    failpoints.reset()

    check_terminal(afe_c, streams, stats)
    # token parity: every stream that COMPLETED under chaos matches the
    # healthy run byte-for-byte — faults degrade speed, not results
    for s in streams:
        if s.completed:
            assert list(s.tokens) == want[s.rid], \
                f"seed {seed}: stream {s.rid} diverged under {spec}"
    # deadline accounting ties out
    assert stats.expired == sum(s.cancelled for s in streams)
    assert stats.cancelled == stats.expired


def test_degradation_never_changes_results_kernel_ladder(f32_model,
                                                         prog_dir):
    """Knock out the whole planned rung (every Pallas variant raises at
    lowering) and serve: tokens must be identical to the healthy run
    while the engine reports the demotions."""
    eng_h, afe_h = make_afe(f32_model, prog_dir)
    healthy, _ = afe_h.simulate(chaos_trace(99))
    want = {s.rid: list(s.tokens) for s in healthy}

    failpoints.configure({"kernels.lower.skinny": "raise",
                          "kernels.lower.tall": "raise",
                          # force retrace so lowering actually re-runs
                          "programs.deserialize": "raise"})
    # drop jax's jit/lowering cache too: the healthy engine shares the
    # module-scoped model object, and a cached lowering would replay
    # WITHOUT re-running the Python trace (and thus the ladder)
    jax.clear_caches()
    eng_c, afe_c = make_afe(f32_model, prog_dir)
    streams, _ = afe_c.simulate(chaos_trace(99))
    failpoints.reset()
    assert {s.rid: list(s.tokens) for s in streams} == want
    rep = eng_c.health_report()
    assert not rep["healthy"]
    assert rep["degradations"]["by_seam"].get("kernel.variant", 0) >= 1


# ---------------------------------------------------------------------------
# queue chaos: exactly-once completion under faults + contention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(3))
def test_queue_chaos_exactly_once(tmp_path, seed):
    from repro.tuning.queue import JobQueue, TuneJob

    rng = np.random.default_rng(seed)
    n_jobs = 6
    q = JobQueue(tmp_path / "q.json", lock_timeout_s=30.0)
    q.enqueue([TuneJob(problem_key=f"p{i}", platform="cpu")
               for i in range(n_jobs)])

    # injected chaos: occasional write failure (bounded so the run
    # terminates), lock-acquire delays to widen contention windows
    failpoints.configure(
        {"queue.replace.before": {"action": "raise",
                                  "p": float(rng.choice([0.2, 0.4])),
                                  "times": int(rng.integers(3, 8))},
         "queue.lock.acquire": {"action": "delay", "delay_s": 0.002,
                                "p": 0.5}},
        seed=seed)

    def worker(wid):
        while True:
            try:
                job = q.claim(wid, lease_s=60.0)
            except Exception:
                continue                 # injected fault: retry
            if job is None:
                return
            for _ in range(50):          # complete must eventually land
                try:
                    if q.complete(job.job_id, wid, result="ok"):
                        break
                except Exception:
                    continue
                break                    # lease lost (not possible here)

    threads = [threading.Thread(target=worker, args=(f"w{i}",))
               for i in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not any(t.is_alive() for t in threads)
    failpoints.reset()

    jobs = q.jobs()
    assert len(jobs) == n_jobs
    for j in jobs.values():
        assert j.state == "done", (j.job_id, j.state, j.history)
        done_events = [h for h in j.history if h[0] == "done"]
        assert len(done_events) == 1, j.history   # exactly-once
