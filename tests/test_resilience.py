"""Resilience plane (DESIGN.md §16): failpoints, degradation ladder,
deadlines/cancellation, torn persistence, and the lock-steal fix.

Everything deterministic: failpoint probability draws come from a
seeded RNG, serving runs on the VirtualClock, and the lock hammer
asserts mutual exclusion exactly.
"""

import asyncio
import json
import os
import subprocess
import sys
import time
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.resilience import degrade, failpoints
from repro.resilience.failpoints import InjectedFault


@pytest.fixture(autouse=True)
def _clean_failpoints():
    failpoints.reset()
    yield
    failpoints.reset()


# ---------------------------------------------------------------------------
# failpoint registry semantics
# ---------------------------------------------------------------------------


def test_unarmed_is_noop():
    failpoints.fp("nothing.armed")
    assert failpoints.corrupt("nothing.armed", b"data") == b"data"
    assert failpoints.report() == {}


def test_raise_action_and_times_cap():
    failpoints.configure({"a.b": {"action": "raise", "times": 2}})
    fired = 0
    for _ in range(5):
        try:
            failpoints.fp("a.b")
        except InjectedFault:
            fired += 1
    assert fired == 2
    rep = failpoints.report()["a.b"]
    assert rep["fired"] == 2 and rep["hits"] == 5


def test_probability_is_seeded():
    def run(seed):
        failpoints.reset()
        failpoints.configure({"p.site": {"action": "raise", "p": 0.5}},
                             seed=seed)
        out = []
        for _ in range(64):
            try:
                failpoints.fp("p.site")
                out.append(0)
            except InjectedFault:
                out.append(1)
        return out

    a, b, c = run(7), run(7), run(8)
    assert a == b                        # same seed -> same schedule
    assert a != c                        # different seed -> different draw
    assert 10 < sum(a) < 54              # actually probabilistic


def test_compact_spec_and_json_spec():
    failpoints.configure("x=raise:times=1;y=delay:delay_s=0.25:p=0.5")
    rep = failpoints.report()
    assert rep["x"] == {"action": "raise", "p": 1.0, "times": 1,
                        "hits": 0, "fired": 0}
    assert rep["y"]["action"] == "delay" and rep["y"]["p"] == 0.5
    failpoints.reset()
    failpoints.configure('{"z": {"action": "corrupt", "times": 3}}')
    assert failpoints.report()["z"]["times"] == 3


def test_unknown_action_rejected():
    with pytest.raises(ValueError, match="unknown failpoint action"):
        failpoints.configure({"s": "explode"})
    with pytest.raises(ValueError, match="unknown keys"):
        failpoints.configure({"s": {"action": "raise", "bogus": 1}})


def test_delay_charges_virtual_clock():
    from repro.serve.clock import VirtualClock
    clock = VirtualClock()
    failpoints.configure({"d": {"action": "delay", "delay_s": 0.5}})
    t0 = clock.now()
    failpoints.fp("d", clock=clock)
    assert clock.now() - t0 == pytest.approx(0.5)


def test_corrupt_tears_bytes_and_str():
    failpoints.configure({"c": "corrupt"})
    b = failpoints.corrupt("c", b"0123456789")
    s = failpoints.corrupt("c", "0123456789")
    assert b != b"0123456789" and b.startswith(b"01234")
    assert s != "0123456789" and s.startswith("01234")
    # a corrupt action on a control-flow site degenerates to raise
    with pytest.raises(InjectedFault):
        failpoints.fp("c")


def test_env_arming_and_tune_crash_alias(monkeypatch):
    monkeypatch.setenv(failpoints.ENV_CONFIG, "e.site=raise:times=2")
    monkeypatch.setenv(failpoints.ENV_TUNE_CRASH, "after-claim")
    failpoints.reset()
    rep = failpoints.report()
    assert rep["e.site"]["times"] == 2
    # the pre-§16 worker hook aliases onto the plane as a crash action
    assert rep["worker.claim.after"]["action"] == "crash"
    monkeypatch.setenv(failpoints.ENV_TUNE_CRASH, "after-everything")
    failpoints.reset()
    assert "worker.claim.after" not in failpoints.report()  # unknown: warn


def test_bad_env_config_never_raises(monkeypatch):
    monkeypatch.setenv(failpoints.ENV_CONFIG, "{not json at all")
    failpoints.reset()
    failpoints.fp("anything")            # must not raise
    assert failpoints.report() == {}


# ---------------------------------------------------------------------------
# degradation bookkeeping
# ---------------------------------------------------------------------------


def test_circuit_breaker_opens_and_resets():
    br = degrade.CircuitBreaker(threshold=3)
    assert br.allow("k")
    assert not br.failure("k") and not br.failure("k")
    br.success("k")                      # clean pass resets the count
    assert not br.failure("k") and not br.failure("k")
    assert br.failure("k")               # third consecutive: opens
    assert not br.allow("k")
    assert br.allow("other")
    assert br.report()["open"] == ["k"]


def test_degrade_stats_contextvar_routing():
    mine = degrade.DegradeStats()
    with degrade.use(mine):
        degrade.record("seam.a", key="k1", fallback="fb")
        degrade.record("seam.a")
    degrade.record("seam.b")             # outside: goes to GLOBAL
    assert mine.counts == {"seam.a": 2}
    rep = mine.report()
    assert rep["total"] == 2 and rep["events"][0]["fallback"] == "fb"
    assert degrade.GLOBAL.counts.get("seam.b", 0) >= 1


# ---------------------------------------------------------------------------
# torn persistence: every durability seam degrades, never raises
# ---------------------------------------------------------------------------


def test_registry_load_survives_torn_file(tmp_path):
    from repro.core import registry
    p = tmp_path / "plans.json"
    p.write_text('{"plans": {"x": {"truncated...')
    assert registry._read_json(p) is None  # warn, not raise


def test_queue_load_quarantines_torn_file(tmp_path):
    from repro.tuning.queue import QUEUE_SCHEMA, JobQueue, TuneJob
    qp = tmp_path / "queue.json"
    qp.write_text('{"schema": 1, "jobs": {"a/b": {"problem_')
    q = JobQueue(qp)
    stats = degrade.DegradeStats()
    with degrade.use(stats):
        assert q.jobs() == {}            # torn file -> empty, no raise
    assert stats.counts.get("queue.file") == 1
    assert (tmp_path / "queue.json.corrupt").exists()  # forensics kept
    # the queue restarts cleanly after quarantine
    q.enqueue([TuneJob(problem_key="m4096_k4096_n16_bfloat16_s1",
                       platform="cpu")])
    assert q.status()["pending"] == 1
    # wrong schema is quarantined too (incl. valid-JSON-non-dict)
    qp.write_text(json.dumps([1, 2, 3]))
    assert q.jobs() == {}


def test_program_cache_survives_zero_byte_entry(tmp_path):
    from repro.serve.programs import ProgramStore

    store = ProgramStore.__new__(ProgramStore)  # _load only needs cache_dir
    store.cache_dir = tmp_path
    (tmp_path / "deadbeef.prog").write_bytes(b"")
    stats = degrade.DegradeStats()
    with degrade.use(stats):
        assert store._load("deadbeef") is None  # warn + retrace, no raise
    assert stats.counts.get("program.disk") == 1


def test_find_db_survives_torn_file(tmp_path, monkeypatch):
    from repro.tuning import find_db
    p = tmp_path / "find.json"
    p.write_text('{"schema": "find_db/1", "plans": {"trunc')
    stats = degrade.DegradeStats()
    with degrade.use(stats):
        assert find_db.read_find_db(p, strict=False) == {}
    assert stats.counts.get("registry.find_db") == 1
    with pytest.raises(Exception):
        find_db.read_find_db(p, strict=True)


def test_registry_flush_defers_on_write_failure(tmp_path):
    from repro.core.autotuner import make_plan
    from repro.core.plan import Problem
    from repro.core.registry import Registry
    reg = Registry(plan_path=tmp_path / "plans.json",
                   measure_path=tmp_path / "measure.json")
    plan = make_plan(Problem(4096, 4096, 16), persist=False)
    failpoints.configure(
        {"registry.flush.before_replace": {"action": "raise"}})
    stats = degrade.DegradeStats()
    with degrade.use(stats):
        reg.put(plan, persist=True)      # write fails -> deferred, no raise
    assert stats.counts.get("registry.flush", 0) >= 1
    # memory stays authoritative
    assert reg.get(plan.problem.key()) is not None
    failpoints.reset()
    with degrade.use(stats):
        reg.flush()                      # disarmed: the deferred write lands
    assert (tmp_path / "plans.json").exists()


def test_miss_log_restashes_on_write_failure(tmp_path):
    from repro.core.registry import Registry
    reg = Registry(plan_path=tmp_path / "plans.json",
                   measure_path=tmp_path / "measure.json")
    miss = tmp_path / "misses.json"
    assert reg.get("m4096_k4096_n16_bfloat16_s1") is None  # records a miss
    failpoints.configure(
        {"registry.misses.before_replace": {"action": "raise"}})
    stats = degrade.DegradeStats()
    with degrade.use(stats):
        assert reg.flush_misses(miss) == 0  # failed write -> re-stashed
    assert stats.counts.get("registry.misses") == 1
    assert not miss.exists()
    failpoints.reset()
    assert reg.flush_misses(miss) == 1   # nothing was lost
    assert miss.exists()


# ---------------------------------------------------------------------------
# file-lock steal race (two breakers must not both win)
# ---------------------------------------------------------------------------


def test_stale_break_is_exclusive(tmp_path):
    from repro.tuning.queue import _FileLock
    lock_dir = tmp_path / "q.lock"
    lock_dir.mkdir()
    old = time.time() - 3600
    os.utime(lock_dir, (old, old))       # a crashed holder's stale lock
    a = _FileLock(lock_dir, timeout_s=1.0, stale_s=30.0)
    b = _FileLock(lock_dir, timeout_s=0.2, stale_s=30.0)
    a.__enter__()                        # breaks the stale lock, acquires
    assert (lock_dir / "owner").read_text() == a.token
    with pytest.raises(TimeoutError):
        b.__enter__()                    # a's FRESH lock must NOT be stolen
    a.__exit__(None, None, None)
    with b:                              # now free
        assert (lock_dir / "owner").read_text() == b.token
    assert not lock_dir.exists()


def test_exit_does_not_remove_foreign_lock(tmp_path):
    from repro.tuning.queue import _FileLock
    lock_dir = tmp_path / "q.lock"
    a = _FileLock(lock_dir, timeout_s=1.0)
    b = _FileLock(lock_dir, timeout_s=1.0)
    with a:
        b.__exit__(None, None, None)     # not the owner: must be a no-op
        assert lock_dir.exists()
        assert (lock_dir / "owner").read_text() == a.token
    assert not lock_dir.exists()


_HAMMER = r"""
import sys, time
from repro.tuning.queue import _FileLock
lock_path, counter, n = sys.argv[1], sys.argv[2], int(sys.argv[3])
from pathlib import Path
for _ in range(n):
    with _FileLock(Path(lock_path), timeout_s=30.0, stale_s=0.4):
        v = int(Path(counter).read_text())
        time.sleep(0.002)                 # widen the race window
        Path(counter).write_text(str(v + 1))
print("ok")
"""


def test_two_process_lock_hammer_with_stale_breaks(tmp_path):
    """Regression for the double-break race: two processes increment a
    read-modify-write counter under the lock while the stale threshold
    (0.4s) is short enough that breaks genuinely happen against slow
    holders.  Any lost increment = two processes inside the critical
    section at once."""
    lock_dir = tmp_path / "c.lock"
    counter = tmp_path / "counter"
    counter.write_text("0")
    lock_dir.mkdir()                     # pre-existing stale lock
    old = time.time() - 3600
    os.utime(lock_dir, (old, old))
    n = 25
    env = dict(os.environ, PYTHONPATH="src")
    procs = [subprocess.Popen(
        [sys.executable, "-c", _HAMMER, str(lock_dir), str(counter), str(n)],
        env=env, cwd=str(Path(__file__).resolve().parent.parent),
        stdout=subprocess.PIPE, stderr=subprocess.PIPE) for _ in range(2)]
    outs = [p.communicate(timeout=120) for p in procs]
    assert all(p.returncode == 0 for p in procs), outs
    assert int(counter.read_text()) == 2 * n


# ---------------------------------------------------------------------------
# worker claim retry + harvest expiry
# ---------------------------------------------------------------------------


class _FlakyQueue:
    def __init__(self, failures):
        self.failures = failures
        self.claims = 0

    def claim(self, *a, **k):
        self.claims += 1
        if self.failures > 0:
            self.failures -= 1
            raise TimeoutError("injected lock timeout")
        return None                      # queue dry


def test_worker_retries_transient_claim_failures():
    from repro.tuning.worker import run_worker
    q = _FlakyQueue(failures=2)
    report = run_worker(q, worker_id="w", poll_s=0.0)
    assert q.claims == 3                 # 2 failures + 1 clean dry claim
    assert report.done == 0 and report.failed == 0


def test_worker_gives_up_after_retry_budget():
    from repro.tuning.worker import CLAIM_RETRIES, run_worker
    q = _FlakyQueue(failures=99)
    report = run_worker(q, worker_id="w", poll_s=0.0)
    assert q.claims == CLAIM_RETRIES + 1
    assert report.done == 0 and report.failed == 0


def test_expire_stale_drops_only_quiet_pending(tmp_path):
    from repro.tuning.queue import JobQueue, TuneJob
    now = [1000.0]
    q = JobQueue(tmp_path / "q.json", clock=lambda: now[0])
    q.enqueue([
        TuneJob(problem_key="m4096_k4096_n16_bfloat16_s1", platform="cpu",
                last_seen=100.0),
        TuneJob(problem_key="m4096_k4096_n32_bfloat16_s1", platform="cpu",
                last_seen=990.0),
    ])
    leased = q.claim("w", lease_s=60.0)  # leased jobs are never expired
    assert leased is not None
    assert q.expire_stale(max_age_s=500.0) == (
        1 if leased.problem_key.endswith("n32_bfloat16_s1") else 0)
    states = {j.problem_key: j.state for j in q.jobs().values()}
    assert any(s == "leased" for s in states.values())


def test_harvest_expire_after(tmp_path):
    from repro.tuning.queue import JobQueue, TuneJob, harvest
    now = [5000.0]
    q = JobQueue(tmp_path / "q.json", clock=lambda: now[0])
    # a stale pending job from an old harvest: no engine misses on it
    q.enqueue([TuneJob(problem_key="m8192_k4096_n16_bfloat16_s1",
                       platform="cpu", last_seen=10.0)])
    # fresh miss log for a different problem
    miss = tmp_path / "misses.json"
    miss.write_text(json.dumps({
        "cpu/m4096_k4096_n16_bfloat16_s1": {"count": 3,
                                            "last_seen": 4999.0}}))
    counts = harvest(q, miss_path=miss, top_candidates=2,
                     expire_after_s=600.0)
    assert counts["harvested"] == 1 and counts["expired"] == 1
    keys = {j.problem_key for j in q.jobs().values()}
    assert keys == {"m4096_k4096_n16_bfloat16_s1"}  # fresh survives


# ---------------------------------------------------------------------------
# kernel degradation ladder (numerics preserved at every rung)
# ---------------------------------------------------------------------------


def _tsmm_operands(m=2048, k=512, n=16, seed=0):
    # shapes must satisfy is_tsmm (skinny<=256, tall>=8*skinny, k>=512)
    # or tsmm_dot skips planning and the ladder never runs
    rng = np.random.default_rng(seed)
    a = jax.numpy.asarray(rng.standard_normal((m, k)), jax.numpy.float32)
    b = jax.numpy.asarray(rng.standard_normal((k, n)), jax.numpy.float32)
    return a, b


def test_ladder_rung2_xla_twin_matches_planned():
    from repro.core.tsmm import tsmm_dot
    a, b = _tsmm_operands()
    healthy = np.asarray(tsmm_dot(a, b))
    failpoints.configure({"kernels.lower.skinny": "raise",
                          "kernels.lower.tall": "raise"})
    stats = degrade.DegradeStats()
    with degrade.use(stats):
        degraded = np.asarray(tsmm_dot(a, b))
    assert stats.counts.get("kernel.variant", 0) >= 1
    np.testing.assert_array_equal(healthy, degraded)


def test_ladder_rung3_gemm_matches_planned():
    from repro.core.tsmm import tsmm_dot
    a, b = _tsmm_operands(seed=1)
    healthy = np.asarray(tsmm_dot(a, b))
    failpoints.configure({"kernels.lower.skinny": "raise",
                          "kernels.lower.tall": "raise",
                          "kernels.xla.skinny": "raise",
                          "kernels.xla.tall": "raise"})
    stats = degrade.DegradeStats()
    with degrade.use(stats):
        degraded = np.asarray(tsmm_dot(a, b))
    assert stats.counts.get("kernel.xla", 0) >= 1
    np.testing.assert_array_equal(healthy, degraded)


def test_breaker_pins_fallback_after_k_failures():
    from repro.core.tsmm import tsmm_dot
    a, b = _tsmm_operands(seed=2)
    failpoints.configure({"kernels.lower.skinny": "raise",
                          "kernels.lower.tall": "raise"})
    stats = degrade.DegradeStats(breaker_threshold=2)
    with degrade.use(stats):
        for _ in range(4):
            tsmm_dot(a, b)
    # first 2 calls fail the planned rung; after that the breaker is
    # open and the fallback is pinned without re-attempting
    assert stats.counts.get("kernel.variant") == 2
    assert stats.counts.get("kernel.pinned", 0) >= 2
    assert stats.breaker.report()["open"]


# ---------------------------------------------------------------------------
# request deadlines, cancellation, retry (serving level)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def f32_model():
    from repro.configs import get_reduced_config
    from repro.models.registry import build_model
    cfg = get_reduced_config("qwen1_5_4b").reduced(
        d_model=512, d_ff=1024, num_layers=2, vocab_size=1024,
        num_heads=8, num_kv_heads=8, head_dim=64, dtype="float32")
    model = build_model(cfg)
    params, axes = model.init(jax.random.PRNGKey(0))
    return model, params, axes


def make_afe(f32_model, **kw):
    from repro.serve.clock import VirtualClock
    from repro.serve.engine import Engine
    from repro.serve.frontend import AsyncEngine
    model, params, axes = f32_model
    eng = Engine(model, params, axes, max_len=256, max_batch=2,
                 max_prompt=32, prepack=False)
    return eng, AsyncEngine(eng, clock=VirtualClock(), **kw)


def _req(rid, n=6, steps=4, arrival=0.0, deadline=None, seed=0):
    from repro.serve.scheduler import Request
    rng = np.random.default_rng(seed + (rid if isinstance(rid, int) else 0))
    return Request(tokens=rng.integers(0, 1024, size=n).astype(np.int32),
                   max_new_tokens=steps, rid=rid, arrival_time=arrival,
                   deadline=deadline)


def test_deadline_none_is_byte_identical(f32_model):
    """The no-deadline contract: a trace without deadlines serves
    exactly as before §16 (same tokens, same telemetry counts)."""
    _, afe1 = make_afe(f32_model)
    trace = [_req(i, arrival=i * 0.001) for i in range(6)]
    s1, st1 = afe1.simulate(trace)
    _, afe2 = make_afe(f32_model)
    s2, st2 = afe2.simulate([_req(i, arrival=i * 0.001) for i in range(6)])
    assert [s.tokens for s in s1] == [s.tokens for s in s2]
    assert st1.cancelled == 0 and st1.expired == 0
    assert st1.admitted == st2.admitted == 6


def test_deadline_expires_queued_request(f32_model):
    _, afe = make_afe(f32_model)
    # 2 slots; three long requests occupy the engine, the fourth has a
    # deadline that lapses while it waits in queue
    trace = [_req(i, steps=10, arrival=0.0) for i in range(3)]
    trace.append(_req("doomed", steps=4, arrival=0.0, deadline=1e-6))
    streams, stats = afe.simulate(trace)
    doomed = next(s for s in streams if s.rid == "doomed")
    assert doomed.cancelled and doomed.done and not doomed.completed
    assert doomed.tokens == []
    assert stats.expired == 1 and stats.cancelled == 1
    # everyone else finished; no slot leak
    assert stats.completed == 3
    assert sorted(afe.sched.free) == list(range(afe.sched.slots))


def test_deadline_reclaims_running_slot_mid_decode(f32_model):
    from repro.serve.clock import StepCost
    cost = StepCost()
    _, afe = make_afe(f32_model)
    # deadline ~3 decode steps after t=0: the stream is cancelled
    # MID-decode with partial tokens, freeing its slot for the queued one
    deadline = cost.prefill_s(8) + 3.5 * cost.decode_step_s
    trace = [_req(0, steps=50, arrival=0.0, deadline=deadline),
             _req(1, steps=50, arrival=0.0, deadline=deadline),
             _req(2, steps=3, arrival=0.0)]      # waits for a freed slot
    streams, stats = afe.simulate(trace)
    s0, s1, s2 = streams
    assert s0.cancelled and s1.cancelled
    assert 0 < len(s0.tokens) < 50               # partial stream delivered
    assert s0.result is not None and not s0.result.completed
    assert s2.completed and len(s2.tokens) == 3  # admitted into freed slot
    assert stats.expired == 2 and stats.cancelled == 2
    assert sorted(afe.sched.free) == list(range(afe.sched.slots))


def test_cooperative_cancel_via_asyncio(f32_model):
    _, afe = make_afe(f32_model)

    async def scenario():
        s_long = await afe.submit(_req(0, steps=50))
        s_short = await afe.submit(_req(1, steps=3))
        got = 0
        async for _ in s_long:
            got += 1
            if got == 2:
                s_long.cancel()          # cooperative: next tick reaps it
        afe.request_stop()
        return s_long, s_short, got

    async def main():
        task = asyncio.ensure_future(scenario())
        await afe.run()
        return await task

    s_long, s_short, got = asyncio.run(main())
    assert s_long.cancelled and not s_long.completed
    assert got < 50
    assert s_short.completed and len(s_short.tokens) == 3
    assert afe.stats.cancelled == 1 and afe.stats.expired == 0


def test_submit_retry_recovers_from_transient_faults(f32_model):
    _, afe = make_afe(f32_model)
    failpoints.configure(
        {"frontend.admit": {"action": "raise", "times": 2}})

    async def main():
        # run() re-arms _running at entry, so stop AFTER draining the
        # stream — a request_stop() issued before run() would be lost
        run = asyncio.ensure_future(afe.run())
        stream = await afe.submit_retry(_req(0, steps=2), retries=3,
                                        backoff_s=0.01)
        toks = [t async for t in stream]
        afe.request_stop()
        await run
        return stream, toks

    stream, toks = asyncio.run(main())
    assert stream.completed and len(toks) == 2


def test_submit_retry_exhausts_and_raises(f32_model):
    _, afe = make_afe(f32_model)
    failpoints.configure({"frontend.admit": "raise"})

    async def main():
        with pytest.raises(Exception, match="transient admission"):
            await afe.submit_retry(_req(0), retries=2, backoff_s=0.001)

    asyncio.run(main())


def test_health_report_zero_on_happy_path(f32_model):
    eng, afe = make_afe(f32_model)
    streams, stats = afe.simulate([_req(i, arrival=i * 0.001)
                                   for i in range(4)])
    hr = eng.health_report()
    assert hr["healthy"], hr
    assert hr["degradations"]["total"] == 0
    assert all(s.completed for s in streams)
