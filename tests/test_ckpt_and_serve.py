"""Checkpoint manager + serving engine system tests."""

import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.manager import CheckpointManager
from repro.configs import get_reduced_config
from repro.models.registry import build_model
from repro.serve.engine import Engine, pack_tree_for_serving


def test_ckpt_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    tree = {"a": jnp.arange(12).reshape(3, 4).astype(jnp.float32),
            "b": {"c": jnp.ones((5,), jnp.bfloat16)},
            "n": jnp.asarray(3, jnp.int32)}
    for step in (1, 2, 3):
        mgr.save(step, jax.tree.map(lambda x: x + step, tree))
    assert mgr.latest_step() == 3
    assert sorted(mgr.all_steps()) == [2, 3]          # keep=2 GC'd step 1
    got = mgr.restore(3, jax.eval_shape(lambda: tree))
    want = jax.tree.map(lambda x: x + 3, tree)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_ckpt_async_then_restore(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3, async_save=True)
    tree = {"w": jnp.full((64, 64), 2.0)}
    mgr.save(10, tree)
    mgr.wait()
    step, got = mgr.restore_latest(jax.eval_shape(lambda: tree))
    assert step == 10
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))


def test_ckpt_ignores_partial_tmp(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    mgr.save(5, {"x": jnp.ones(3)})
    # a crashed writer leaves a tmp dir behind — must be invisible
    (tmp_path / "step_000000000009.tmp.123.456").mkdir()
    assert mgr.latest_step() == 5


@pytest.fixture(scope="module")
def small_model():
    cfg = get_reduced_config("qwen1_5_4b").reduced(
        d_model=512, d_ff=1024, num_layers=2, vocab_size=1024,
        num_heads=8, num_kv_heads=8, head_dim=64)
    model = build_model(cfg)
    params, axes = model.init(jax.random.PRNGKey(0))
    return model, params, axes


def test_pack_tree_selects_big_weights(small_model):
    model, params, axes = small_model
    packed, report = pack_tree_for_serving(params, axes, batch_m=4)
    assert len(report) >= 4            # attn + mlp + head weights packed
    assert all("tok" not in k for k in report)   # embedding never packed


def test_packed_serving_matches_dense(small_model):
    model, params, axes = small_model
    batch = {"tokens": (jnp.arange(4 * 12).reshape(4, 12)
                        % model.cfg.vocab_size).astype(jnp.int32)}
    packed, _ = pack_tree_for_serving(params, axes, batch_m=4)
    cache = model.init_cache(4, 32)
    l_dense, c1 = model.prefill(params, batch, cache)
    l_packed, c2 = model.prefill(packed, batch, cache)
    np.testing.assert_allclose(np.asarray(l_packed), np.asarray(l_dense),
                               rtol=5e-2, atol=5e-1)
    t = jnp.zeros((4, 1), jnp.int32)
    s_dense, _ = model.decode_step(params, c1, t)
    s_packed, _ = model.decode_step(packed, c2, t)
    np.testing.assert_allclose(np.asarray(s_packed), np.asarray(s_dense),
                               rtol=5e-2, atol=5e-1)


def test_engine_generates(small_model):
    model, params, axes = small_model
    eng = Engine(model, params, axes, max_len=48, batch_size=4, prepack=True)
    batch = {"tokens": (jnp.arange(4 * 12).reshape(4, 12)
                        % model.cfg.vocab_size).astype(jnp.int32)}
    res = eng.generate(batch, steps=6)
    assert res.tokens.shape == (4, 6)
    assert len(eng.pack_report) > 0
    assert bool(jnp.isfinite(res.logits_last.astype(jnp.float32)).all())
